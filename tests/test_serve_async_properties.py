"""Property tests for the event-driven continuous engine (PR 9): any
random arrival schedule and op mix driven through the virtual-clock
stepped loop must be (a) bit-exact with sequential solo execution of
each request and (b) lifecycle-sound — every admitted ticket reaches
exactly one terminal outcome, observed through ``add_done_callback``.

Self-skips when hypothesis is unavailable (it is not part of the
pinned environment), like tests/test_properties.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from serve_sim import SimHarness  # noqa: E402
from repro.serve import Service, VirtualClock  # noqa: E402

pytestmark = pytest.mark.serve

# (op kind, straggler?, image seed) — reconstruction dominates because
# it is the refillable path; qdt exercises the two-output session.
_arrival = st.tuples(
    st.sampled_from(["reconstruct", "reconstruct", "qdt"]),
    st.booleans(),
    st.integers(0, 4),
    st.integers(1, 8),   # inter-arrival gap, virtual ms
)


def _payload(kind, slow, seed, shape=(24, 24)):
    rng = np.random.default_rng(seed)
    if kind == "qdt":
        return ((rng.random(shape) > 0.4).astype(np.float32),)
    h, w = shape
    if slow:
        f = np.full(shape, 0.1, np.float32)
        for r in range(0, h, 2):
            f[r, :] = 0.9
            if r + 1 < h:
                f[r + 1, -1 if (r // 2) % 2 == 0 else 0] = 0.9
        m = np.full(shape, 0.05, np.float32)
        m[0, 0] = 0.8
    else:
        f = rng.random(shape).astype(np.float32)
        m = (0.9 * f).astype(np.float32)
    return (np.minimum(m, f), f)


def _sequential_reference(arrivals):
    """Each request solo through a fresh max_batch=1 batch-path
    service: the sequential-execution baseline the engine must match
    bit for bit (including degraded partial fixpoints — the budget is
    identical)."""
    out = []
    for kind, slow, seed, _gap in arrivals:
        svc = Service(max_batch=1, max_delay_ms=1e9, pad_quantum=16,
                      clock=VirtualClock())
        t = svc.submit(kind, *_payload(kind, slow, seed))
        svc.flush()
        out.append((t.outcome, t.value))
    return out


@settings(max_examples=8, deadline=None)
@given(st.lists(_arrival, min_size=1, max_size=6))
def test_async_schedule_bit_exact_vs_sequential(arrivals):
    harness = SimHarness(continuous=True, max_batch=4, refill_quantum=2,
                         max_delay_ms=2.0, pad_quantum=16)
    t = 0.0
    schedule = []
    for kind, slow, seed, gap in arrivals:
        t += gap * 1e-3
        schedule.append((t, kind, _payload(kind, slow, seed), None, None))
    tickets = harness.play(schedule)
    harness.run_until_idle()
    reference = _sequential_reference(arrivals)
    for tk, (ref_outcome, ref_value) in zip(tickets, reference):
        assert tk is not None and tk.done
        assert tk.outcome == ref_outcome
        got = tk.value if isinstance(tk.value, tuple) else (tk.value,)
        ref = ref_value if isinstance(ref_value, tuple) else (ref_value,)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@settings(max_examples=8, deadline=None)
@given(st.lists(_arrival, min_size=1, max_size=6),
       st.sampled_from([None, 1.0, 25.0]))
def test_every_ticket_exactly_one_terminal_outcome(arrivals, deadline_ms):
    """Exactly-once termination: each admitted ticket's done callback
    fires once, its outcome is terminal, and tight deadlines resolve
    as typed expiries rather than lost tickets."""
    harness = SimHarness(continuous=True, max_batch=4, refill_quantum=2,
                         max_delay_ms=3.0, pad_quantum=16)
    completions: dict[int, int] = {}
    t = 0.0
    for kind, slow, seed, gap in arrivals:
        t += gap * 1e-3
        harness.step_until(t)
        tk = harness.submit(kind, *_payload(kind, slow, seed),
                            deadline_ms=deadline_ms)
        if tk is not None:
            tk.add_done_callback(
                lambda done_t: completions.__setitem__(
                    done_t.request_id,
                    completions.get(done_t.request_id, 0) + 1))
    harness.run_until_idle()
    assert len(completions) == len(harness.tickets)
    assert set(completions.values()) <= {1}  # exactly once, never twice
    for tk in harness.tickets:
        assert tk.done and tk.outcome != "pending"
        assert tk.outcome in ("ok", "degraded", "deadline")
        if tk.outcome == "deadline":
            assert tk.error is not None and tk.value is None
        else:
            assert tk.error is None and tk.value is not None
