"""Property test: serving any shuffled mixed-shape/dtype request stream
through ``repro.serve`` is bit-exact vs calling each operator directly
on the unpadded image (the bucketing/padding/demux machinery must be
invisible in the outputs).

Self-skips when hypothesis is unavailable (it is not part of the pinned
environment), like tests/test_properties.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import operators as OPS  # noqa: E402
from repro.kernels import ops as K  # noqa: E402
from repro.serve import Service  # noqa: E402

pytestmark = pytest.mark.serve

_OPS = ("hmax", "hfill", "erode", "dilate")


def _make_image(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if np.dtype(dtype).kind == "f":
        return rng.uniform(0.0, 1.0, shape).astype(dtype)
    return rng.integers(0, 255, shape).astype(dtype)


def _direct(op, f):
    fj = jnp.asarray(f)
    if op == "hmax":
        return OPS.hmax(fj, 20 if f.dtype == np.uint8 else 0.1)
    if op == "hfill":
        return OPS.hfill(fj)
    if op == "erode":
        return K.erode(fj, 3, backend="xla")
    return K.dilate(fj, 3, backend="xla")


_request = st.tuples(
    st.sampled_from(_OPS),
    st.integers(8, 40),            # H
    st.integers(8, 40),            # W
    st.sampled_from(["uint8", "float32"]),
    st.integers(0, 5),             # image seed
)


@settings(max_examples=15, deadline=None)
@given(st.lists(_request, min_size=1, max_size=8))
def test_serve_stream_roundtrip(reqs):
    svc = Service(backend="xla", max_batch=4, max_delay_ms=1e9,
                  pad_quantum=16)
    tickets = []
    for op, h, w, dtype, seed in reqs:
        f = _make_image((h, w), np.dtype(dtype), seed)
        params = ({"h": 20 if dtype == "uint8" else 0.1} if op == "hmax"
                  else {"s": 3} if op in ("erode", "dilate") else {})
        tickets.append((op, f, svc.submit(op, f, params=params)))
    svc.flush()
    for op, f, t in tickets:
        np.testing.assert_array_equal(
            np.asarray(t.result()), np.asarray(_direct(op, f)),
            err_msg=f"{op} on {f.shape} {f.dtype}")
