"""2-D (row band × column tile) scheduling: planning policy, edge
cases, and bit-exactness of the tiled paths against the dense
references.

The tile axis must be invisible in the outputs — every path (tiled full
grid, tiled compaction, batched stacks converging raggedly) pins the
Pallas driver against the pure-jnp ``core.morphology`` oracles with
``assert_array_equal`` — while the stats must show the 2-D grid
actually skips the column strips a row-band scheduler re-processes.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import morphology as M
from repro.core import operators as OPS
from repro.core.chain import plan_chain
from repro.kernels import ops


def _reference(marker, mask, op):
    if op == "erode":
        return M.erode_reconstruct(marker, mask)
    return M.dilate_reconstruct(marker, mask)


def _vertical_corridor(h, w, col_lo, col_hi):
    """Mask with one narrow vertical corridor + the seed marker at its
    top — the worst case for a row-band scheduler (every full-width
    band stays active until its slice of the corridor converges)."""
    mask = np.zeros((h, w), np.uint8)
    mask[8 : h - 8, col_lo:col_hi] = 200
    marker = np.zeros((h, w), np.uint8)
    marker[8, col_lo + 2] = 200
    return np.minimum(marker, mask), mask


# ---------------------------------------------------------------------------
# planning policy: auto-tiling and the row-only fallbacks
# ---------------------------------------------------------------------------


def test_plan_auto_tiles_convergent():
    p = plan_chain(256, 640, np.uint8, None, convergent=True)
    assert p.tile_w and p.n_tiles >= 2
    assert p.tile_w % p.fuse_k == 0 and p.width_pad % p.tile_w == 0
    assert p.total_tiles == p.total_bands * p.n_tiles
    # key must distinguish tiled from row-only schedules
    p_row = plan_chain(256, 640, np.uint8, None, convergent=True, tile_w=0)
    assert p.key != p_row.key
    # non-convergent plans never auto-tile
    assert plan_chain(256, 640, np.uint8, 8).tile_w == 0


def test_plan_fuse_k_gt_tile_w_falls_back_row_only():
    p = plan_chain(256, 256, np.uint8, None, convergent=True, tile_w=16)
    assert p.fuse_k == 32  # uint8 planning default
    assert p.tile_w == 0 and p.n_tiles == 1  # 16 < fuse_k: row-only
    # compact capacity stays in band units on the fallback
    assert p.compact_capacity <= p.total_bands


def test_plan_single_tile_wide_falls_back_row_only():
    # image narrower than two lane-groups: nothing to split
    assert plan_chain(256, 96, np.uint8, None, convergent=True).tile_w == 0
    # a requested tile as wide as the image is row-only too
    assert plan_chain(256, 256, np.uint8, None, convergent=True,
                      tile_w=256).tile_w == 0


def test_plan_tile_validation():
    from repro.core.chain import ChainPlan
    with pytest.raises(ValueError, match="multiple of"):
        ChainPlan(32, 32, 256, 128, 4, 1, tile_w=48)   # 48 % fuse_k != 0
    with pytest.raises(ValueError, match="width_pad"):
        ChainPlan(32, 32, 384, 128, 4, 1, tile_w=256)  # 384 % 256 != 0


# ---------------------------------------------------------------------------
# bit-exactness of the tiled paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["erode", "dilate"])
def test_tiled_reconstruct_exact(rng, op):
    shape = (160, 250)  # pads to 160 × 256 → 2 column tiles
    mask = rng.integers(20, 180, shape).astype(np.uint8)
    if op == "erode":
        marker = np.full(shape, 255, np.uint8)
        marker[37, 61] = mask[37, 61]
    else:
        marker = np.zeros(shape, np.uint8)
        marker[37, 61] = 200
        marker = np.minimum(marker, mask)
    plan = plan_chain(*shape, np.uint8, None, n_images_resident=2,
                      convergent=True)
    assert plan.n_tiles == 2  # the tiled path actually runs
    out = ops.reconstruct(jnp.asarray(marker), jnp.asarray(mask), op,
                          "pallas", plan=plan)
    want = _reference(jnp.asarray(marker), jnp.asarray(mask), op)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_tiled_compaction_branch_exact():
    """Corridor confined to one tile column: activity collapses below
    the compact threshold, so the patch-gather compact path runs on the
    2-D grid and must stay bit-exact (including the cached mask-patch
    gather)."""
    marker, mask = _vertical_corridor(256, 640, 320, 336)
    plan = plan_chain(256, 640, np.uint8, None, n_images_resident=2,
                      convergent=True)
    assert plan.n_tiles >= 4
    out, stats = ops.reconstruct_with_stats(
        jnp.asarray(marker), jnp.asarray(mask), "dilate", "pallas",
        plan=plan)
    want = M.dilate_reconstruct(jnp.asarray(marker), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    per_chunk = np.asarray(stats.active_per_chunk)[: int(stats.chunks)]
    assert (per_chunk <= plan.compact_capacity).any()  # compaction ran


def test_tiled_qdt_exact(rng):
    f = rng.integers(0, 255, (160, 250)).astype(np.uint8)
    plan = plan_chain(160, 250, np.uint8, None, n_images_resident=3,
                      convergent=True)
    assert plan.n_tiles == 2
    d, r = ops.qdt_planes(jnp.asarray(f), backend="pallas", plan=plan)
    dw, rw = OPS.qdt_raw(jnp.asarray(f))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rw))


def test_tiled_ragged_batched_stack(rng):
    """Images converging at different tile counts in one stack: a
    trivially-converged image, a corridor image whose wavefront lives in
    one tile column, and a busy full-frame image.  Each must match its
    solo reference exactly (per-image halo pinning on both axes, and
    per-image QDT-style chunk counters on the reconstruction side)."""
    H, W = 128, 256
    mask_full = np.full((H, W), 200, np.uint8)
    done = mask_full.copy()
    corridor_m, corridor_k = _vertical_corridor(H, W, 130, 140)
    busy_k = rng.integers(20, 220, (H, W)).astype(np.uint8)
    busy_m = np.zeros((H, W), np.uint8)
    busy_m[64, 128] = 255
    busy_m = np.minimum(busy_m, busy_k)

    markers = jnp.asarray(np.stack([done, corridor_m, busy_m]))
    masks = jnp.asarray(np.stack([mask_full, corridor_k, busy_k]))
    plan = plan_chain(H, W, np.uint8, None, n_images_resident=2,
                      n_images=3, convergent=True)
    assert plan.n_tiles == 2
    out = ops.reconstruct(markers, masks, "dilate", "pallas", plan=plan)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(out[i]),
            np.asarray(M.dilate_reconstruct(markers[i], masks[i])))


# ---------------------------------------------------------------------------
# the scheduling win: vertical wavefronts skip column strips
# ---------------------------------------------------------------------------


def test_vertical_wavefront_fewer_tile_executions():
    """Acceptance criterion: on a narrow vertical corridor the 2-D
    scheduler executes fewer tiles than the row-band scheduler on the
    same input.  Row bands are normalized to tile-executions (one band
    spans ``n_tiles`` tiles of area)."""
    marker, mask = _vertical_corridor(256, 640, 320, 336)
    mj, kj = jnp.asarray(marker), jnp.asarray(mask)
    tiled = plan_chain(256, 640, np.uint8, None, n_images_resident=2,
                       convergent=True)
    row = plan_chain(256, 640, np.uint8, None, n_images_resident=2,
                     convergent=True, tile_w=0)
    assert tiled.n_tiles >= 4 and row.n_tiles == 1
    out_t, st = ops.reconstruct_with_stats(mj, kj, "dilate", "pallas",
                                           plan=tiled)
    out_r, sr = ops.reconstruct_with_stats(mj, kj, "dilate", "pallas",
                                           plan=row)
    want = M.dilate_reconstruct(mj, kj)
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(want))
    tiled_cells = int(st.active_band_sum)
    row_cells = int(sr.active_band_sum) * tiled.n_tiles
    assert tiled_cells < row_cells, (
        f"2-D scheduler did not skip column strips: {tiled_cells} "
        f"tile-executions vs {row_cells} row-band-equivalents")
